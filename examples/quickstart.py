"""Quickstart: the Moniqua codec in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Round-trip one tensor through the modulo-quantized codec (Lemmas 1-2).
2. Gossip 8 decentralized workers one round and watch consensus tighten.
3. Train a tiny LM with Moniqua vs full-precision D-PSGD and compare both
   the loss and the bytes on the wire.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.comm import gossip
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.models.model_factory import build_model
from repro.train.trainer import Trainer, TrainerConfig


def demo_codec():
    print("=== 1. codec round-trip (Lemma 1/2) ===")
    theta = 2.0                      # a-priori bound on |x - y|
    codec = MoniquaCodec(QuantSpec(bits=4, stochastic=True))
    y = jax.random.normal(jax.random.PRNGKey(0), (8,)) * 10.0   # receiver's model
    x = y + jax.random.uniform(jax.random.PRNGKey(1), (8,),
                               minval=-0.9, maxval=0.9) * theta  # sender's
    packed = codec.encode(x, theta, jax.random.PRNGKey(2))
    x_hat = codec.decode(packed, y, theta)
    print(f"payload: {packed.nbytes} bytes for {x.nbytes} bytes of f32 "
          f"({8 * packed.nbytes / x.size:.0f} bits/param)")
    print(f"max |x_hat - x| = {float(jnp.max(jnp.abs(x_hat - x))):.4f}"
          f"  (Lemma-2 bound {codec.max_error(theta):.4f})")


def demo_gossip():
    print("\n=== 2. one quantized gossip round ===")
    topo = ring(8)
    codec = MoniquaCodec(QuantSpec(bits=8))
    X = jax.random.normal(jax.random.PRNGKey(0), (8, 128)) * 0.3
    spread0 = float(jnp.abs(X - X.mean(0)).max())
    X1 = gossip.moniqua_gossip(X, topo, codec, theta=2.0,
                               key=jax.random.PRNGKey(1))
    spread1 = float(jnp.abs(X1 - X1.mean(0)).max())
    print(f"worker spread before {spread0:.4f} -> after {spread1:.4f} "
          f"(consensus tightening with 1-byte payloads)")


def demo_training():
    print("\n=== 3. tiny decentralized training run ===")
    import dataclasses
    cfg = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                              num_layers=1, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=64)
    model = build_model(cfg)
    shape = InputShape("qs", seq_len=16, global_batch=8, kind="train")
    for algo, bits in [("dpsgd", 32), ("moniqua", 8)]:
        tc = TrainerConfig(algo=algo, n_workers=4, bits=min(bits, 8),
                           theta=2.0, lr=0.3, steps=20, log_every=10,
                           momentum=0.0, weight_decay=0.0)
        out = Trainer(model, shape, tc).run()
        h = out["history"]
        print(f"{algo:8s} ({bits:2d}-bit wire): loss {h[0]['loss']:.3f} -> "
              f"{h[-1]['loss']:.3f}   bytes/step/worker "
              f"{out['bytes_per_step']:,}")


if __name__ == "__main__":
    demo_codec()
    demo_gossip()
    demo_training()
