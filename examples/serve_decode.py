"""Serve a small model with batched decode requests (deliverable b, serving).

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b

Builds the reduced architecture, prefills a batch of prompts, then decodes
tokens autoregressively with the KV / recurrent-state cache — the same
``serve_step`` the decode dry-run shapes (decode_32k, long_500k) lower.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import assigned_archs, get_config
from repro.configs.base import InputShape
from repro.models.model_factory import build_model
from repro.train import serve_step as SS


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-3b", choices=assigned_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write per-token decode-latency spans as "
                         "Chrome-trace JSON (open in Perfetto)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    shape = InputShape("serve", seq_len=args.context,
                       global_batch=args.batch, kind="decode")
    cache = model.init_cache(args.batch, shape)
    step = jax.jit(SS.make_serve_step(model))

    key = jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab_size,
                             dtype=jnp.int32)

    from repro.obs.trace import SpanRecorder
    rec = SpanRecorder()

    # warmup/compile
    with rec.span("decode.compile", tid="serve"):
        logits, cache = step(params, cache, tok)
        jax.block_until_ready(logits)

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.tokens):
        # block per token so each span is a real decode latency, not a
        # dispatch time (the usual serving TPOT measurement)
        with rec.span("decode.token", tid="serve", token=i):
            logits, cache = step(params, cache, out_tokens[-1])
            nxt = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)
            jax.block_until_ready(nxt)
        out_tokens.append(nxt.reshape(args.batch, 1).astype(jnp.int32))
    dt = time.time() - t0

    seqs = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={args.arch} (reduced) batch={args.batch} "
          f"cache_len={args.context}")
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s on CPU)")
    spans = [s for s in rec.events if s["name"] == "decode.token"]
    lat = sorted(s["dur_s"] for s in spans)
    p50 = lat[len(lat) // 2]
    p95 = lat[min(int(len(lat) * 0.95), len(lat) - 1)]
    print(f"per-token latency: p50={p50*1e3:.2f}ms p95={p95*1e3:.2f}ms")
    if args.trace:
        rec.save(args.trace, process_name="serve")
        print(f"wrote decode-latency trace to {args.trace}")
    for b in range(min(args.batch, 2)):
        print(f"  seq[{b}]: {seqs[b, :16].tolist()} ...")
    assert bool(jnp.isfinite(logits).all())


if __name__ == "__main__":
    main()
