"""Decentralized data with Moniqua on D^2 (paper Sec. 5 / Fig. 2a).

    PYTHONPATH=src python examples/hetero_d2.py

Every worker owns ONE class of a synthetic classification task (maximal
outer variance — the paper's 1-label-per-worker CIFAR split).  Plain D-PSGD's
local models are dragged to their local optima; D^2 cancels the variance and
Moniqua-on-D^2 does the same with quantized payloads.
"""
import jax
import jax.numpy as jnp

from repro.core.algorithms import get_algorithm
from repro.core.moniqua import MoniquaCodec
from repro.core.quantizers import QuantSpec
from repro.core.topology import ring
from repro.core.algorithms import AlgoHyper

N, D, CLASSES = 8, 64, 8
ALPHA, STEPS = 0.1, 600


def main():
    key = jax.random.PRNGKey(0)
    # per-class optima: worker i only ever sees class i -> grad f_i = x - c_i
    c = 4.0 * jax.random.normal(key, (N, D))
    c_bar = jnp.mean(c, axis=0)

    topo = ring(N).slack(0.75)        # D^2 needs lambda_n > -1/3
    hp = AlgoHyper(topo=topo, codec=MoniquaCodec(QuantSpec(bits=8)),
                   theta=2.0)

    for name in ("dpsgd", "d2", "moniqua_d2"):
        algo = get_algorithm(name)
        X = jnp.zeros((N, D))
        extra = algo.init(X, hp)
        kk = jax.random.PRNGKey(1)

        @jax.jit
        def step(X, extra, k, kk):
            kk, kg, ka = jax.random.split(kk, 3)
            g = X - c + 0.05 * jax.random.normal(kg, (N, D))
            Xn, en = algo.step(X, extra, g, ALPHA, k, ka, hp)
            return Xn, en, kk

        for k in range(STEPS):
            X, extra, kk = step(X, extra, jnp.asarray(k), kk)
        local_err = float(jnp.mean(jnp.sum((X - c_bar) ** 2, axis=1)))
        print(f"{name:12s} per-worker error to global optimum: "
              f"{local_err:10.4f}")
    print("\nD-PSGD stalls at the outer-variance floor; D^2 and "
          "Moniqua-D^2 converge (Theorem 4), the latter at 1/4 the bytes.")


if __name__ == "__main__":
    main()
